"""Layer-2 JAX model: the two machine datapaths + the SmallCNN e2e network.

Every convolution can be executed through either machine's functional model:

* :func:`conv2d_systolic` — the digital in-memory path (paper Fig. 2):
  im2col Toeplitz rearrangement, 8-bit symmetric quantization, and the
  weight-stationary tiled matmul Pallas kernel with int32 accumulation.
* :func:`conv2d_fft` — the optical 4F path (paper Figs. 4-5): zero-pad,
  2-D FFT (the first lens, eigenvector matrix U), B-bit SLM quantization of
  both spectra (the DACs driving the metasurfaces), the Fourier-plane
  pointwise Pallas kernel (the diagonal eigenvalue operator Lambda), inverse
  FFT (the second lens, U^T), VALID crop, and ADC quantization of the
  measured field.

Both reduce to plain HLO via interpret-mode Pallas, so ``aot.py`` can lower
any of these graphs to HLO text for the Rust/PJRT runtime. Python never
runs at serving time.
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from .kernels import qmatmul, fourier_pointwise
from .kernels.ref import im2col
from .quant import (
    fake_quantize,
    fake_quantize_per_leading,
    quantize_per_leading,
    quantize_symmetric,
)

ConvPath = Literal["systolic", "fft", "exact"]


def _block_for(dim: int, target: int = 128) -> int:
    """Pick a block size: ``target`` if the padded cost is acceptable."""
    return min(target, max(8, dim)) if dim < target else target


def _pad2(a: jax.Array, bl: int, bn: int) -> jax.Array:
    p0 = (-a.shape[0]) % bl
    p1 = (-a.shape[1]) % bn
    if p0 or p1:
        a = jnp.pad(a, ((0, p0), (0, p1)))
    return a


def conv2d_systolic(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int = 1,
    bits: int = 8,
) -> jax.Array:
    """VALID conv on the weight-stationary systolic machine.

    x: (Ci, H, W) f32; w: (Co, Ci, k, k) f32 -> (Co, H', W') f32.

    Activations get one scale per layer invocation (the accumulator feeds a
    single requantizer per port); weights get one scale per output channel
    (scales travel with the weight tile loaded from DRAM).
    """
    co, ci, k, _ = w.shape
    cols = im2col(x, k, stride)  # (L, N) with N = k*k*Ci
    wmat = w.reshape(co, ci * k * k).T  # (N, M)

    xq, sx = quantize_symmetric(cols, bits)
    wq_t, sw = quantize_per_leading(w.reshape(co, -1), bits)  # scales per Co
    wq = wq_t.T  # (N, M) codes

    bl, bn, bm = (
        _block_for(xq.shape[0]),
        _block_for(xq.shape[1]),
        _block_for(wq.shape[1]),
    )
    acc = qmatmul(
        jnp.astype(_pad2(xq, bl, bn), jnp.int32),
        jnp.astype(_pad2(wq, bn, bm), jnp.int32),
        block_l=bl,
        block_n=bn,
        block_m=bm,
    )[: xq.shape[0], : wq.shape[1]]

    y = acc.astype(jnp.float32) * sx * sw[None, :]  # dequantize (L, M)
    ho = (x.shape[1] - k) // stride + 1
    wo = (x.shape[2] - k) // stride + 1
    return y.T.reshape(co, ho, wo)


def _fft_block_h(h: int, target: int = 8) -> int:
    """Largest divisor of ``h`` that is <= target (grid must tile H exactly)."""
    for b in range(min(target, h), 0, -1):
        if h % b == 0:
            return b
    return 1


def conv2d_fft(
    x: jax.Array,
    w: jax.Array,
    *,
    bits: int | None = 8,
    adc_bits: int | None = None,
) -> jax.Array:
    """VALID conv on the reflection-mode optical 4F machine (stride 1).

    x: (Ci, H, W) f32; w: (Co, Ci, k, k) f32 -> (Co, H-k+1, W-k+1) f32.

    ``bits`` models the SLM/DAC precision applied to both spectra (the
    loading phase writes the activation spectrum to the Fourier-plane SLM;
    the compute phase writes kernels to the object-plane SLM).
    ``adc_bits`` models the CIS readout. ``None`` disables either quantizer
    (ideal converters), which the tests use to isolate kernel correctness.
    """
    ci, h, w_ = x.shape
    co, _, k, _ = w.shape
    s0, s1 = h + k - 1, w_ + k - 1

    xf = jnp.fft.rfft2(x, s=(s0, s1))  # phase 1: optical FFT of activations
    kf = jnp.conj(jnp.fft.rfft2(w, s=(s0, s1)))  # kernel spectra (correlation)

    # SLM write precision: independent real/imag quadratures, one scale per
    # activation load and per kernel tile (each tile normalized to the
    # modulator dynamic range).
    xr = fake_quantize(jnp.real(xf).astype(jnp.float32), bits)
    xi = fake_quantize(jnp.imag(xf).astype(jnp.float32), bits)
    kr = fake_quantize_per_leading(jnp.real(kf).astype(jnp.float32), bits)
    ki = fake_quantize_per_leading(jnp.imag(kf).astype(jnp.float32), bits)

    yr, yi = fourier_pointwise(xr, xi, kr, ki, block_h=_fft_block_h(s0))

    y = jnp.fft.irfft2(yr + 1j * yi, s=(s0, s1))  # second lens: U^T
    y = y[:, : h - k + 1, : w_ - k + 1]  # non-wrapping VALID region
    return fake_quantize(y.astype(jnp.float32), adc_bits)


def conv2d_fft_tiled(
    x: jax.Array,
    w: jax.Array,
    *,
    bits: int | None = None,
) -> jax.Array:
    """VALID conv via the paper's Fig. 4 parallel-channel tiling.

    All Cᵢ input channels are tiled onto ONE object-plane canvas (stacked
    along the rows with n-row spacing); for each output channel the
    matching kernels are tiled at the same offsets. A single Fourier
    transform of the canvas and one pointwise product then produce the
    *channel-summed* convolution in the canvas' top-left n-k+1 window —
    "one complete output channel is produced per measurement" — because
    same-channel correlation terms land at the common window while all
    cross-channel terms land at row offsets >= n-k+1 (and the circular
    wraparound stays outside too, since H = Ci*n + k - 1).

    This is the mechanism that makes eq. (22)'s C' channel packing work;
    numerically verified against :func:`conv2d_exact` in the tests.
    """
    ci, n, n2 = x.shape
    assert n == n2, "square inputs"
    co, _, k, _ = w.shape
    h_canvas = ci * n + k - 1
    w_canvas = n + k - 1

    # Object-plane canvas: channel j occupies rows [j*n, j*n + n).
    canvas = jnp.zeros((h_canvas, w_canvas), x.dtype)
    for j in range(ci):
        canvas = canvas.at[j * n : (j + 1) * n, :n].set(x[j])
    # Kernel canvases: kernel (o, j) at rows [j*n, j*n + k).
    kern = jnp.zeros((co, h_canvas, w_canvas), x.dtype)
    for j in range(ci):
        kern = kern.at[:, j * n : j * n + k, :k].set(w[:, j])

    xf = jnp.fft.rfft2(canvas)  # one optical FFT for ALL channels
    kf = jnp.conj(jnp.fft.rfft2(kern))  # (Co, H, Wf)

    xr = fake_quantize(jnp.real(xf).astype(jnp.float32), bits)[None]
    xi = fake_quantize(jnp.imag(xf).astype(jnp.float32), bits)[None]
    kr = fake_quantize_per_leading(jnp.real(kf).astype(jnp.float32), bits)[:, None]
    ki = fake_quantize_per_leading(jnp.imag(kf).astype(jnp.float32), bits)[:, None]

    yr, yi = fourier_pointwise(xr, xi, kr, ki, block_h=_fft_block_h(h_canvas))
    y = jnp.fft.irfft2(yr + 1j * yi, s=(h_canvas, w_canvas))
    return y[:, : n - k + 1, : n - k + 1]


def conv2d_exact(x: jax.Array, w: jax.Array, *, stride: int = 1) -> jax.Array:
    """f32 oracle conv (XLA native) — the 'infinite-precision' datapath."""
    out = jax.lax.conv_general_dilated(
        x[None],
        w,
        window_strides=(stride, stride),
        padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out[0]


def conv2d(
    x: jax.Array, w: jax.Array, *, path: ConvPath, stride: int = 1
) -> jax.Array:
    if path == "systolic":
        return conv2d_systolic(x, w, stride=stride)
    if path == "fft":
        assert stride == 1, "4F machine computes stride-1 convs"
        return conv2d_fft(x, w)
    return conv2d_exact(x, w, stride=stride)


def avg_pool2(x: jax.Array) -> jax.Array:
    """2x2 mean pool over (C, H, W), truncating odd edges."""
    c, h, w = x.shape
    x = x[:, : h - h % 2, : w - w % 2]
    return x.reshape(c, h // 2, 2, w // 2, 2).mean(axis=(2, 4))


# --------------------------------------------------------------------------
# SmallCNN: the end-to-end workload (examples/e2e_inference.rs).
# --------------------------------------------------------------------------

SMALLCNN_CHANNELS = (3, 8, 16, 32, 32)
SMALLCNN_K = 3
SMALLCNN_CLASSES = 10
SMALLCNN_INPUT = (3, 64, 64)


def smallcnn_init(seed: int = 0) -> dict[str, jax.Array]:
    """Deterministic He-initialized parameters (fixed across python/rust)."""
    key = jax.random.PRNGKey(seed)
    params: dict[str, jax.Array] = {}
    chans = SMALLCNN_CHANNELS
    for i, (ci, co) in enumerate(zip(chans[:-1], chans[1:])):
        key, k1 = jax.random.split(key)
        fan_in = ci * SMALLCNN_K * SMALLCNN_K
        params[f"conv{i}"] = (
            jax.random.normal(k1, (co, ci, SMALLCNN_K, SMALLCNN_K))
            * jnp.sqrt(2.0 / fan_in)
        ).astype(jnp.float32)
    key, k1 = jax.random.split(key)
    params["head"] = (
        jax.random.normal(k1, (chans[-1], SMALLCNN_CLASSES))
        * jnp.sqrt(1.0 / chans[-1])
    ).astype(jnp.float32)
    return params


def smallcnn_forward(
    params: dict[str, jax.Array], x: jax.Array, *, path: ConvPath
) -> jax.Array:
    """x (3, 64, 64) -> logits (10,). Pools after the first three convs."""
    n_convs = len(SMALLCNN_CHANNELS) - 1
    for i in range(n_convs):
        x = conv2d(x, params[f"conv{i}"], path=path)
        x = jax.nn.relu(x)
        if i < 3:
            x = avg_pool2(x)
    feat = x.mean(axis=(1, 2))  # global average pool -> (C,)
    return feat @ params["head"]


def smallcnn(x: jax.Array, *, path: ConvPath, seed: int = 0) -> jax.Array:
    """Self-contained forward with baked parameters (for AOT lowering)."""
    return smallcnn_forward(smallcnn_init(seed), x, path=path)


@functools.partial(jax.jit, static_argnames=("path",))
def smallcnn_jit(x: jax.Array, path: ConvPath = "exact") -> jax.Array:
    return smallcnn(x, path=path)
