"""Layer-1 Pallas kernels for the two analog-machine datapaths.

``qmatmul``           -- weight-stationary tiled int8 matrix multiply with
                         32-bit accumulation: the functional model of the
                         paper's 256x256 digital systolic array (TPU-like).
``fourier_pointwise`` -- per-output-channel complex multiply-accumulate in
                         the Fourier plane: the functional model of the
                         optical 4F system's diagonal eigenvalue operator
                         (the second, Fourier-plane SLM).

All kernels are lowered with ``interpret=True`` -- the CPU PJRT plugin cannot
execute Mosaic custom-calls; real-TPU resource estimates live in DESIGN.md S7
and EXPERIMENTS.md.
"""

from .qmatmul import qmatmul, qmatmul_f32
from .fourier_pointwise import fourier_pointwise

__all__ = ["qmatmul", "qmatmul_f32", "fourier_pointwise"]
