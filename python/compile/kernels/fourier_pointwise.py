"""Fourier-plane pointwise multiply-accumulate Pallas kernel.

Functional model of the optical 4F system's compute phase (paper Fig. 5b,
eq. 17): after the first lens has produced U x (the 2-D Fourier transform of
the activation data, held on the Fourier-plane SLM), the second SLM applies
the diagonal eigenvalue operator Lambda — an elementwise complex product
with the Fourier transform of the kernel — and the second lens applies U^T.

This kernel is Lambda, fused with the channel reduction: for every output
channel ``co``::

    Y_f[co, h, w] = sum_ci X_f[ci, h, w] * K_f[co, ci, h, w]

The lenses (the static U / U^T eigenvector matrices) remain jnp FFTs in the
Layer-2 model — they are *static optics* in the paper's machine, and XLA's
FFT is already optimal on CPU.

Complex data is carried as separate real/imaginary planes: Pallas interpret
mode (and TPU Mosaic) has no complex vector type, and physically the two
quadratures are measured separately by the interferometric CIS readout
anyway (paper Sec. V: "the complex value of the field can nonetheless be
recovered using interferometric methods").

TPU mapping: grid = (Co, H/bh); each step loads an (Ci, bh, W) slab of the
activation spectrum plus the matching kernel slab into VMEM and reduces over
Ci with FMA — pure VPU work, no MXU. VMEM per step (defaults, Ci<=64,
bh=8, W<=129 rfft bins): 4 slabs * 64*8*129*4 B ~ 1.0 MiB << 16 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fourier_kernel(xr_ref, xi_ref, kr_ref, ki_ref, or_ref, oi_ref):
    """One (co, h-tile) step: complex dot over the input-channel axis."""
    xr = xr_ref[...]  # (Ci, bh, W)
    xi = xi_ref[...]
    kr = kr_ref[0]  # (Ci, bh, W)  — leading block dim of size 1 (this co)
    ki = ki_ref[0]
    # (a + ib)(c + id) = (ac - bd) + i(ad + bc), summed over Ci.
    or_ref[0] = jnp.sum(xr * kr - xi * ki, axis=0)
    oi_ref[0] = jnp.sum(xr * ki + xi * kr, axis=0)


@functools.partial(jax.jit, static_argnames=("block_h",))
def fourier_pointwise(
    xr: jax.Array,
    xi: jax.Array,
    kr: jax.Array,
    ki: jax.Array,
    *,
    block_h: int = 8,
) -> tuple[jax.Array, jax.Array]:
    """Apply the Fourier-plane diagonal operator.

    Args:
      xr, xi: activation spectrum, shape ``(Ci, H, W)`` float32.
      kr, ki: kernel spectrum, shape ``(Co, Ci, H, W)`` float32.
      block_h: H-tile size; H must be a multiple of it.

    Returns:
      (yr, yi): output spectrum, shape ``(Co, H, W)`` float32.
    """
    ci, h, w = xr.shape
    co = kr.shape[0]
    if kr.shape != (co, ci, h, w):
        raise ValueError(f"kernel spectrum {kr.shape} != {(co, ci, h, w)}")
    if xi.shape != xr.shape or ki.shape != kr.shape:
        raise ValueError("real/imag shape mismatch")
    if h % block_h:
        raise ValueError(f"H={h} not a multiple of block_h={block_h}")
    grid = (co, h // block_h)
    x_spec = pl.BlockSpec((ci, block_h, w), lambda c, j: (0, j, 0))
    k_spec = pl.BlockSpec((1, ci, block_h, w), lambda c, j: (c, 0, j, 0))
    o_spec = pl.BlockSpec((1, block_h, w), lambda c, j: (c, j, 0))
    out_sd = jax.ShapeDtypeStruct((co, h, w), jnp.float32)
    return pl.pallas_call(
        _fourier_kernel,
        grid=grid,
        in_specs=[x_spec, x_spec, k_spec, k_spec],
        out_specs=[o_spec, o_spec],
        out_shape=[out_sd, out_sd],
        interpret=True,
    )(xr, xi, kr, ki)
