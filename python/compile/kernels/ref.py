"""Pure-jnp oracles for the Pallas kernels and the Layer-2 conv paths.

These are the CORE correctness signal: every Pallas kernel and every model
datapath is pytest-asserted allclose against the functions in this module.
Nothing here is tiled, quantized-in-kernel, or otherwise clever.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_i32(x: jax.Array, w: jax.Array) -> jax.Array:
    """int8 (L,N) @ int8 (N,M) with exact int32 accumulation."""
    return jax.lax.dot_general(
        x.astype(jnp.int32),
        w.astype(jnp.int32),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def matmul_f32(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def fourier_pointwise(
    xr: jax.Array, xi: jax.Array, kr: jax.Array, ki: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Complex pointwise product + channel reduction, via native complex."""
    x = xr + 1j * xi  # (Ci, H, W)
    k = kr + 1j * ki  # (Co, Ci, H, W)
    y = jnp.einsum("chw,ochw->ohw", x, k)
    return jnp.real(y).astype(jnp.float32), jnp.imag(y).astype(jnp.float32)


def conv2d_valid(x: jax.Array, w: jax.Array, stride: int = 1) -> jax.Array:
    """Direct VALID cross-correlation: x (Ci,H,W), w (Co,Ci,k,k) -> (Co,H',W').

    Matches the convention of deep-learning 'convolution' (no kernel flip),
    which is what both machine datapaths implement.
    """
    out = jax.lax.conv_general_dilated(
        x[None],
        w,
        window_strides=(stride, stride),
        padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out[0]


def im2col(x: jax.Array, k: int, stride: int = 1) -> jax.Array:
    """Toeplitz rearrangement (paper Fig. 2): x (Ci,H,W) -> (L, k*k*Ci).

    L = H' * W' with H' = (H-k)//stride + 1. Column ordering is
    (ci, dy, dx) fastest-last, matching ``w.reshape(Co, -1).T`` for OIHW
    weights.
    """
    ci, h, w_ = x.shape
    ho = (h - k) // stride + 1
    wo = (w_ - k) // stride + 1
    patches = []
    for dy in range(k):
        for dx in range(k):
            patches.append(
                jax.lax.slice(
                    x,
                    (0, dy, dx),
                    (ci, dy + (ho - 1) * stride + 1, dx + (wo - 1) * stride + 1),
                    (1, stride, stride),
                )
            )
    # (k*k, Ci, Ho, Wo) -> (Ho*Wo, Ci*k*k) with (ci, dy, dx) ordering.
    stack = jnp.stack(patches, axis=0).reshape(k, k, ci, ho, wo)
    stack = stack.transpose(3, 4, 2, 0, 1)  # (Ho, Wo, Ci, k, k)
    return stack.reshape(ho * wo, ci * k * k)


def conv2d_via_matmul(x: jax.Array, w: jax.Array, stride: int = 1) -> jax.Array:
    """Reference conv-as-matmul (the systolic-array algorithm, paper Fig. 2)."""
    co, ci, k, _ = w.shape
    cols = im2col(x, k, stride)  # (L, k*k*Ci)
    wmat = w.reshape(co, ci * k * k).T  # (k*k*Ci, Co)
    h = (x.shape[1] - k) // stride + 1
    wdt = (x.shape[2] - k) // stride + 1
    return (cols @ wmat).T.reshape(co, h, wdt)


def conv2d_via_fft(x: jax.Array, w: jax.Array) -> jax.Array:
    """Reference conv-as-FFT (the optical 4F algorithm, paper Sec. V).

    Linear VALID cross-correlation through padded circular convolution:
    correlate(x, w) = ifft( fft(x) * conj(fft(w)) ) with both zero-padded
    to (H + k - 1).
    """
    ci, h, w_ = x.shape
    co, _, k, _ = w.shape
    s0, s1 = h + k - 1, w_ + k - 1
    xf = jnp.fft.rfft2(x, s=(s0, s1))  # (Ci, s0, s1//2+1)
    kf = jnp.fft.rfft2(w, s=(s0, s1))  # (Co, Ci, ...)
    yf = jnp.einsum("chw,ochw->ohw", xf, jnp.conj(kf))
    y = jnp.fft.irfft2(yf, s=(s0, s1))  # circular correlation, (Co, s0, s1)
    # Non-wrapping (VALID) region of the circular correlation is [0, H-k].
    return y[:, : h - k + 1, : w_ - k + 1]
