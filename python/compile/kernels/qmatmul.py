"""Weight-stationary tiled quantized matmul Pallas kernel.

Functional model of the paper's digital in-memory compute datapath: a
256x256 weight-stationary systolic array (Google TPUv1-like) computing
``activations (L, N) @ weights (N, M)`` with 8-bit operands and a 32-bit
accumulator (the paper, Sec. VII.A: "The activations and weights are 8-bit
fixed point" with a 32-bit accumulation register per tile).

TPU mapping (DESIGN.md "Hardware adaptation"): the weight-stationary MAC
plane becomes an MXU-style tiled matmul. The grid is (L/bl, M/bm, N/bn)
with the contraction dimension innermost so each (bl, bm) output tile stays
resident in VMEM across the N-sweep — exactly the partial-sum-stationary
accumulation a systolic column performs. BlockSpec expresses the HBM->VMEM
schedule the hardware would do with its activation/weight FIFOs.

VMEM footprint per step (defaults bl=bm=bn=128):
    x tile   128*128*4 B =  64 KiB   (int32-widened int8 activations)
    w tile   128*128*4 B =  64 KiB
    acc      128*128*4 B =  64 KiB
  total ~192 KiB << 16 MiB VMEM; the MXU sees dense 128x128 int8 GEMM tiles
  (100% utilization modulo edge padding, which the wrapper zero-pads).

interpret=True throughout (CPU PJRT cannot run Mosaic custom-calls).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _qmatmul_kernel(x_ref, w_ref, o_ref, *, n_steps: int):
    """One grid step: o[bl,bm] (+)= x[bl,bn] @ w[bn,bm] in int32.

    The innermost grid dimension walks the contraction axis; on the first
    step the accumulator tile is zeroed, afterwards it accumulates in place
    (partial-sum-stationary, like the systolic array's accumulator column).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    o_ref[...] += jax.lax.dot_general(
        x,
        w,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


@functools.partial(jax.jit, static_argnames=("block_l", "block_n", "block_m"))
def qmatmul(
    x: jax.Array,
    w: jax.Array,
    *,
    block_l: int = 128,
    block_n: int = 128,
    block_m: int = 128,
) -> jax.Array:
    """Quantized matmul: int8 ``x (L, N)`` @ int8 ``w (N, M)`` -> int32 (L, M).

    Dimensions must be multiples of the block sizes; callers zero-pad
    (zero-padding is exact for matmul). See :func:`pad_to_blocks`.
    """
    l, n = x.shape
    n2, m = w.shape
    if n != n2:
        raise ValueError(f"contraction mismatch: {x.shape} @ {w.shape}")
    if l % block_l or n % block_n or m % block_m:
        raise ValueError(
            f"dims {(l, n, m)} not multiples of blocks {(block_l, block_n, block_m)}"
        )
    n_steps = n // block_n
    grid = (l // block_l, m // block_m, n_steps)
    kernel = functools.partial(_qmatmul_kernel, n_steps=n_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_l, block_n), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_n, block_m), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_l, block_m), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((l, m), jnp.int32),
        interpret=True,
    )(x, w)


def _f32_matmul_kernel(x_ref, w_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block_l", "block_n", "block_m"))
def qmatmul_f32(
    x: jax.Array,
    w: jax.Array,
    *,
    block_l: int = 128,
    block_n: int = 128,
    block_m: int = 128,
) -> jax.Array:
    """f32 variant of the same tiled schedule (used by the exact-path CNN).

    Same BlockSpec schedule as :func:`qmatmul` so the HBM<->VMEM traffic
    model is identical; only the element type changes (bf16/f32 MXU mode).
    """
    l, n = x.shape
    n2, m = w.shape
    if n != n2:
        raise ValueError(f"contraction mismatch: {x.shape} @ {w.shape}")
    if l % block_l or n % block_n or m % block_m:
        raise ValueError(
            f"dims {(l, n, m)} not multiples of blocks {(block_l, block_n, block_m)}"
        )
    grid = (l // block_l, m // block_m, n // block_n)
    return pl.pallas_call(
        _f32_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_l, block_n), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_n, block_m), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_l, block_m), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((l, m), jnp.float32),
        interpret=True,
    )(x, w)


def pad_to_blocks(a: jax.Array, blocks: tuple[int, ...]) -> jax.Array:
    """Zero-pad each dim of ``a`` up to the next multiple of ``blocks``."""
    pads = []
    for dim, b in zip(a.shape, blocks):
        rem = (-dim) % b
        pads.append((0, rem))
    if all(p == (0, 0) for p in pads):
        return a
    return jnp.pad(a, pads)
