"""AOT compile path: lower the Layer-2 graphs to HLO *text* artifacts.

Run once at build time (``make artifacts``); the Rust runtime loads the
artifacts through the PJRT C API and Python never runs again.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Artifacts written to ``artifacts/``:
    <name>.hlo.txt      HLO text of the lowered computation
    <name>.in<i>.f32    golden input i   (raw little-endian f32)
    <name>.out.f32      golden output    (raw little-endian f32)
    manifest.tsv        name, input shapes, output shape, rtol per artifact

Usage: ``python -m compile.aot --out-dir ../artifacts``
"""

from __future__ import annotations

import argparse
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .quant import quantize_symmetric

# Tolerance used by the rust runtime's golden replay tests. Quantized paths
# carry 8-bit converter error; exact paths are float-roundoff only.
RTOL_EXACT = 1e-5
RTOL_QUANT = 5e-2


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def qgemm(x: jax.Array, w: jax.Array) -> jax.Array:
    """f32 GEMM through the full systolic datapath (quantize/compute/dequant).

    Demo artifact exercising the Layer-1 kernel in isolation from Rust.
    """
    from .kernels import qmatmul

    xq, sx = quantize_symmetric(x)
    wq, sw = quantize_symmetric(w)
    acc = qmatmul(xq, wq, block_l=128, block_n=128, block_m=128)
    return acc.astype(jnp.float32) * sx * sw


def _batched(fn, batch: int, *, vectorize: bool):
    """Batch a single-image function.

    ``vectorize=True`` lowers with ``jax.vmap`` — XLA fuses the batch into
    wide ops (measured 2.1× faster than the sequential loop for the exact
    path; see EXPERIMENTS.md §Perf). Interpret-mode Pallas kernels batch
    *slower* under vmap (the interpreter re-traces batched refs), so the
    systolic path keeps the ``lax.map`` while-loop.
    """
    if vectorize:
        return jax.vmap(fn)

    def wrapped(xs):
        return jax.lax.map(fn, xs)

    return wrapped


def build_artifact_specs() -> list[tuple[str, object, list, float]]:
    """(name, fn, example_args, rtol) for every artifact we ship."""
    rng = np.random.default_rng(0xA1C)

    def arr(*shape):
        return jnp.asarray(rng.standard_normal(shape).astype(np.float32))

    specs: list[tuple[str, object, list, float]] = []

    # Layer-1 kernel demo: the systolic GEMM tile path.
    specs.append(
        ("qgemm_256x128x256", qgemm, [arr(256, 128), arr(128, 256)], RTOL_QUANT)
    )

    # Single conv layers, both machine datapaths (runtime integration tests).
    x_c = arr(8, 64, 64)
    w_c = arr(16, 8, 3, 3)
    specs.append(
        (
            "conv_sys_n64_ci8_co16_k3",
            functools.partial(model.conv2d_systolic, bits=8),
            [x_c, w_c],
            RTOL_QUANT,
        )
    )
    specs.append(
        (
            "conv_fft_n64_ci8_co16_k3",
            functools.partial(model.conv2d_fft, bits=8),
            [x_c, w_c],
            RTOL_QUANT,
        )
    )

    # SmallCNN end-to-end, all three paths, parameters baked in.
    x_img = arr(*model.SMALLCNN_INPUT)
    for path, rtol in (
        ("exact", RTOL_EXACT),
        ("systolic", RTOL_QUANT),
        ("fft", RTOL_QUANT),
    ):
        specs.append(
            (
                f"smallcnn_{path}",
                functools.partial(model.smallcnn, path=path),
                [x_img],
                rtol,
            )
        )

    # Batched variants for the coordinator's dynamic batcher.
    for batch in (4, 8):
        xs = arr(batch, *model.SMALLCNN_INPUT)
        for path, rtol in (("exact", RTOL_EXACT), ("systolic", RTOL_QUANT)):
            fn = _batched(
                functools.partial(model.smallcnn, path=path),
                batch,
                vectorize=(path == "exact"),
            )
            specs.append((f"smallcnn_{path}_b{batch}", fn, [xs], rtol))

    return specs


def lower_and_write(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest_lines = []
    for name, fn, args, rtol in build_artifact_specs():
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
            f.write(text)

        # Golden replay data.
        out = np.asarray(jax.jit(fn)(*args))
        for i, a in enumerate(args):
            np.asarray(a, dtype=np.float32).tofile(
                os.path.join(out_dir, f"{name}.in{i}.f32")
            )
        out.astype(np.float32).tofile(os.path.join(out_dir, f"{name}.out.f32"))

        in_shapes = ";".join(
            ",".join(str(d) for d in np.shape(a)) for a in args
        )
        out_shape = ",".join(str(d) for d in out.shape)
        manifest_lines.append(f"{name}\t{in_shapes}\t{out_shape}\t{rtol}")
        print(f"  {name}: {len(text)} chars, out {out.shape}")

    with open(os.path.join(out_dir, "manifest.tsv"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {len(manifest_lines)} artifacts to {out_dir}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    # Back-compat with the original Makefile single-file target.
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    ns = ap.parse_args()
    out_dir = os.path.dirname(ns.out) if ns.out else ns.out_dir
    lower_and_write(out_dir or ".")


if __name__ == "__main__":
    main()
